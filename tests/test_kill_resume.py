"""Preemption end-to-end: SIGKILL a streaming ``kernel_train`` run mid-fit,
``--resume`` it, and require the finished model to match the uninterrupted
run — plus an elastic restore onto a different local device count.

Everything runs through the real CLI in subprocesses (the same idiom as
``test_distributed.py``): the kill arrives from outside the process at an
arbitrary instant, so this exercises the atomic commit protocol exactly
the way a cluster preemption would. A deliberately torn step file is
planted before the resume to prove ``load_latest`` skips it end-to-end.

Same-topology resume is asserted BITWISE: the segmented drivers make the
checkpointed trajectory canonical, so restoring from any committed step
replays the identical float sequence. The elastic restore (1 -> 4 fake
devices) changes psum/reduction grouping, so it gets a tolerance instead.
"""
import os
import shutil
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

pytestmark = [pytest.mark.slow]  # four subprocess training runs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _env(extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    if extra:
        env.update(extra)
    return env


def _cli(data_dir, save, ckpt_dir, *, resume=False):
    cmd = [sys.executable, "-m", "repro.launch.kernel_train",
           "--plan", "stream", "--data-dir", str(data_dir),
           "--m", "32", "--max-iter", "40", "--lam", "1e-3",
           "--sigma", "2.0", "--chunk-rows", "256",
           "--ckpt-interval", "2", "--ckpt-keep", "0",
           "--ckpt-dir", str(ckpt_dir), "--save", str(save)]
    if resume:
        cmd += ["--resume", str(ckpt_dir)]
    return cmd


def _beta(path):
    with np.load(path, allow_pickle=True) as z:
        return np.asarray(z["beta"], dtype=np.float64)


@pytest.fixture(scope="module")
def runs(tmp_path_factory):
    """Shards + four training runs, produced once for every test below:

    ref      uninterrupted WITH checkpointing (the canonical trajectory)
    killed   SIGKILLed right after its first step file committed
    resumed  --resume of the killed run, same topology, to completion
    elastic  --resume of the killed run's steps on 4 fake devices
    """
    root = tmp_path_factory.mktemp("kill_resume")
    data = root / "shards"
    # deterministic separable-ish binary data, written once as mmap shards
    from repro.data.chunks import save_chunks
    rng = np.random.default_rng(7)
    X = rng.standard_normal((2048, 16)).astype(np.float32)
    w = rng.standard_normal(16)
    y = np.where(X @ w + 0.3 * rng.standard_normal(2048) > 0, 1, -1)
    save_chunks(data, X, y.astype(np.int64), rows_per_shard=512)

    out = {}

    # --- reference: uninterrupted, checkpointing on -----------------------
    ref_steps = root / "ref-steps"
    proc = subprocess.run(
        _cli(data, root / "ref.npz", ref_steps), env=_env(),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out["ref_stdout"] = proc.stdout

    # --- kill: SIGKILL as soon as the first step file commits -------------
    kill_steps = root / "kill-steps"
    proc = subprocess.Popen(
        _cli(data, root / "kill.npz", kill_steps), env=_env(),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    deadline = time.time() + 300
    committed = []
    while time.time() < deadline:
        committed = sorted(kill_steps.glob("step-*.npz"))
        if committed:
            break
        if proc.poll() is not None:
            _, err = proc.communicate()
            pytest.fail("training exited before its first checkpoint: "
                        + err[-3000:])
        time.sleep(0.02)
    assert committed, "no step file committed within the deadline"
    proc.kill()                      # SIGKILL: no cleanup handlers run
    proc.communicate()
    out["kill_returncode"] = proc.returncode
    out["first_step"] = committed[0].name

    # elastic restore resumes from a frozen copy of the post-kill state
    elastic_steps = root / "elastic-steps"
    shutil.copytree(kill_steps, elastic_steps)

    # plant a torn "newest" step: load_latest must skip it, not crash
    (kill_steps / "step-99999999.npz").write_bytes(b"PK\x03\x04 torn")

    # --- resume: same topology, to completion -----------------------------
    proc = subprocess.run(
        _cli(data, root / "kill.npz", kill_steps, resume=True), env=_env(),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out["resume_stdout"] = proc.stdout

    # --- elastic: same steps, 4 simulated local devices -------------------
    proc = subprocess.run(
        _cli(data, root / "elastic.npz", elastic_steps, resume=True),
        env=_env({"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out["elastic_stdout"] = proc.stdout

    out["ref_beta"] = _beta(root / "ref.npz")
    out["resumed_beta"] = _beta(root / "kill.npz")
    out["elastic_beta"] = _beta(root / "elastic.npz")
    return out


def test_killed_mid_fit(runs):
    assert runs["kill_returncode"] == -signal.SIGKILL
    assert runs["first_step"].startswith("step-")


def test_resume_announces_committed_step_and_skips_torn_file(runs):
    line = [l for l in runs["resume_stdout"].splitlines()
            if "resuming from step" in l]
    assert line, runs["resume_stdout"]
    step = int(line[0].split("resuming from step")[1].split()[0])
    assert 0 < step < 99999999, "resume picked the torn step file"


def test_resumed_beta_bitwise_matches_uninterrupted(runs):
    ref, res = runs["ref_beta"], runs["resumed_beta"]
    assert ref.shape == res.shape
    assert np.array_equal(ref, res), \
        f"resume diverged: maxdiff={np.max(np.abs(ref - res))}"


def test_elastic_restore_matches_reference(runs):
    assert "resuming from step" in runs["elastic_stdout"]
    ref, ela = runs["ref_beta"], runs["elastic_beta"]
    assert ref.shape == ela.shape
    # 4-way device sharding regroups reductions; trajectories re-round but
    # must land on the same optimum
    denom = max(float(np.max(np.abs(ref))), 1e-12)
    rel = float(np.max(np.abs(ref - ela))) / denom
    assert rel < 1e-3, f"elastic restore drifted: rel maxdiff={rel}"
