"""Supervised restarts: the Supervisor state machine (fast, stub children)
plus the end-to-end acceptance run (slow, real CLI).

Fast tests drive :class:`repro.sharding.supervisor.Supervisor` with tiny
``python -c`` stub workers — no jax, sub-second — to pin the restart
budget, --resume propagation, elastic shrink, backoff recording, and the
attempt-timeout path.

The slow test is the ISSUE's acceptance criterion verbatim: a supervised
streaming ``kernel_train`` fit whose worker SIGKILLs itself mid-run (a
``ckpt.commit`` kill rule, flag-filed so it fires exactly once across
restarts) auto-restarts from the latest committed step and finishes with
a beta BITWISE identical to an uninterrupted supervised run — the
canonical-trajectory guarantee surviving an unattended crash+recovery.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.faults import FaultPlan
from repro.sharding.supervisor import (Supervisor, SupervisorConfig,
                                       SupervisorError)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

QUICK = SupervisorConfig(max_restarts=3, backoff_s=0.01, max_backoff_s=0.02,
                         poll_s=0.01, attempt_timeout_s=60.0)


def _quiet(_):
    pass


def _stub(code):
    """build_cmd for a fixed python -c child (same argv for every pid)."""
    return lambda pid, nproc, port, resume: [sys.executable, "-c", code]


# ------------------------------------------------------------- fast units
def test_crash_twice_then_succeed(tmp_path):
    """A worker that dies twice and then runs clean: the supervisor eats
    both deaths, records per-attempt forensics, and reports success."""
    counter = tmp_path / "crashes-left"
    counter.write_text("2")
    code = (f"import pathlib,sys\np=pathlib.Path({str(counter)!r})\n"
            "n=int(p.read_text())\n"
            "if n>0: p.write_text(str(n-1)); sys.exit(1)\n")
    sleeps = []
    sup = Supervisor(_stub(code), config=QUICK, say=_quiet,
                     sleep=sleeps.append)
    res = sup.run()
    assert res.ok and res.restarts == 2 and not res.shrunk
    assert [a["ok"] for a in res.attempts] == [False, False, True]
    assert res.attempts[0]["returncodes"] == [1]
    assert res.attempts[0]["death_detect_s"] is not None
    # backoff is recorded on the failed attempts and actually slept
    assert len(sleeps) == 2
    assert [a["backoff_s"] for a in res.attempts[:2]] == sleeps
    assert all(s > 0 for s in sleeps)


def test_restart_budget_exhausted_carries_log_tails(tmp_path):
    code = "import sys\nprint('dying noisily')\nsys.exit(3)\n"
    cfg = SupervisorConfig(max_restarts=1, backoff_s=0.01, poll_s=0.01)
    sup = Supervisor(_stub(code), config=cfg, say=_quiet,
                     sleep=_quiet)
    with pytest.raises(SupervisorError, match="giving up") as ei:
        sup.run()
    assert "dying noisily" in str(ei.value)       # forensics attached
    assert len(ei.value.attempts) == 2            # initial + 1 restart


def test_resume_flag_follows_committed_steps(tmp_path):
    """build_cmd sees resume=False until the checkpoint dir holds a
    committed step file, then resume=True on the relaunch."""
    ckpt = tmp_path / "steps"
    ckpt.mkdir()
    seen = []
    code = ("import os,sys\n"
            f"d={str(ckpt)!r}\n"
            "if sys.argv[1]=='resume': sys.exit(0)\n"
            "open(os.path.join(d,'step-00000004.npz'),'w').close()\n"
            "sys.exit(1)\n")

    def build(pid, nproc, port, resume):
        seen.append(resume)
        return [sys.executable, "-c", code, "resume" if resume else "fresh"]

    res = Supervisor(build, ckpt_dir=str(ckpt), config=QUICK,
                     say=_quiet, sleep=_quiet).run()
    assert res.ok and res.restarts == 1
    assert seen == [False, True]
    assert res.attempts[0]["resumed_from"] is None
    assert res.attempts[1]["resumed_from"] == 4


def test_elastic_shrink_to_fewer_processes():
    """Persistent failure at P=2 (a bad host) shrinks the fleet to P=1,
    which succeeds — forward progress instead of a crash loop."""
    code = ("import sys\nsys.exit(1 if sys.argv[1]=='2' else 0)\n")

    def build(pid, nproc, port, resume):
        return [sys.executable, "-c", code, str(nproc)]

    cfg = SupervisorConfig(max_restarts=3, backoff_s=0.01, poll_s=0.01,
                           shrink_after=1, min_processes=1)
    res = Supervisor(build, num_processes=2, config=cfg, say=_quiet,
                     sleep=_quiet).run()
    assert res.ok and res.shrunk and res.final_processes == 1
    assert res.attempts[0]["num_processes"] == 2
    assert res.final_attempt["num_processes"] == 1


def test_hung_fleet_counts_as_failure():
    code = "import time\ntime.sleep(60)\n"
    cfg = SupervisorConfig(max_restarts=0, poll_s=0.01,
                           attempt_timeout_s=0.3)
    with pytest.raises(SupervisorError, match="timed out") as ei:
        Supervisor(_stub(code), config=cfg, say=_quiet, sleep=_quiet).run()
    assert ei.value.attempts[0]["timed_out"]


def test_latest_step_ignores_noise(tmp_path):
    sup = Supervisor(_stub(""), ckpt_dir=str(tmp_path), say=_quiet)
    assert sup.latest_step() is None
    (tmp_path / ".tmp-ckpt-xyz.npz").write_text("")
    (tmp_path / "model.npz").write_text("")
    assert sup.latest_step() is None
    (tmp_path / "step-00000002.npz").write_text("")
    (tmp_path / "step-00000010.npz").write_text("")
    assert sup.latest_step() == 10


def test_rejects_bad_process_count():
    with pytest.raises(ValueError, match="num_processes"):
        Supervisor(_stub(""), num_processes=0)


# ------------------------------------------- slow: end-to-end acceptance
def _env(extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FAULTS", None)
    if extra:
        env.update(extra)
    return env


def _supervised_cli(data_dir, save, ckpt_dir):
    return [sys.executable, "-m", "repro.launch.kernel_train",
            "--supervise", "--max-restarts", "2",
            "--plan", "stream", "--data-dir", str(data_dir),
            "--m", "32", "--max-iter", "40", "--lam", "1e-3",
            "--sigma", "2.0", "--chunk-rows", "256",
            "--ckpt-interval", "2", "--ckpt-keep", "0",
            "--ckpt-dir", str(ckpt_dir), "--save", str(save)]


def _beta(path):
    with np.load(path, allow_pickle=True) as z:
        return np.asarray(z["beta"], dtype=np.float64)


@pytest.mark.slow
def test_supervised_fit_survives_sigkill_bitwise(tmp_path):
    """ISSUE acceptance: SIGKILL mid-run under --supervise; the run
    auto-restarts from the latest checkpoint and the final beta is
    bitwise identical to an uninterrupted supervised run."""
    from repro.data.chunks import save_chunks
    data = tmp_path / "shards"
    rng = np.random.default_rng(7)
    X = rng.standard_normal((2048, 16)).astype(np.float32)
    w = rng.standard_normal(16)
    y = np.where(X @ w + 0.3 * rng.standard_normal(2048) > 0, 1, -1)
    save_chunks(data, X, y.astype(np.int64), rows_per_shard=512)

    # reference: supervised but unfaulted (identical ckpt flags, so the
    # canonical trajectory is shared with the faulted run)
    ref = subprocess.run(
        _supervised_cli(data, tmp_path / "ref.npz", tmp_path / "ref-steps"),
        env=_env(), capture_output=True, text=True, timeout=900)
    assert ref.returncode == 0, ref.stdout[-3000:] + ref.stderr[-3000:]
    assert "restarting" not in ref.stdout

    # faulted: the worker SIGKILLs itself inside its 2nd checkpoint
    # commit; the flag file makes the kill fire exactly once across
    # restarts, so the relaunched worker runs clean to completion
    plan = FaultPlan().inject("ckpt.commit", action="kill", after=1,
                              times=1, flag=str(tmp_path / "killed-once"))
    faulted = subprocess.run(
        _supervised_cli(data, tmp_path / "got.npz", tmp_path / "got-steps"),
        env=_env({"REPRO_FAULTS": plan.to_json()}),
        capture_output=True, text=True, timeout=900)
    assert faulted.returncode == 0, \
        faulted.stdout[-3000:] + faulted.stderr[-3000:]
    assert (tmp_path / "killed-once").exists(), "the kill rule never fired"
    assert "restarting from step" in faulted.stdout, faulted.stdout[-3000:]
    assert "[supervise] done" in faulted.stdout

    b_ref, b_got = _beta(tmp_path / "ref.npz"), _beta(tmp_path / "got.npz")
    assert b_ref.shape == b_got.shape
    assert np.array_equal(b_ref, b_got), \
        f"recovery diverged: maxdiff={np.max(np.abs(b_ref - b_got))}"


@pytest.mark.slow
@pytest.mark.requires_devices(2)
@pytest.mark.requires_multiprocess(timeout=1500)
def test_fleet_stall_changes_no_result_bit():
    """A SIGSTOP/SIGCONT straggler (paused VM) delays the fleet but must
    not change the fit: peers block in the collective until it resumes."""
    from multihost.rig import run_fleet
    clean = run_fleet("fit", 2, 1, extra=["stream"]).result
    stalled = run_fleet("fit", 2, 1, extra=["stream"],
                        faults=FaultPlan().stall(1, 3.0, 2.0)).result
    assert stalled["beta_sha"] == clean["beta_sha"], \
        "a stalled worker changed the result bits"
