"""Teacher-forced forward logits MUST match step-by-step decode logits —
the strongest end-to-end correctness check for every cache implementation
(GQA KV, sliding ring, MLA compressed/absorbed, SSM state, enc-dec cross)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~3 min of per-arch decode loops on CPU

from repro.configs import ARCHS
from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.models.transformer import D_VISION

B, S = 2, 24


def _decode_all(model, params, tokens, cache):
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = model.decode_step(params, tokens[:, t: t + 1], cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache   # (B, S, V)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "llama3.2-1b", "qwen3-4b",
                                  "granite-34b", "grok-1-314b"])
def test_dense_moe_decode_matches_forward(name):
    # capacity_factor high enough that no token is dropped: capacity-based
    # MoE routing otherwise LEGITIMATELY differs between the 48-token
    # teacher-forced groups and the 2-token decode groups (documented
    # train/serve discrepancy of capacity routers).
    cfg = ARCHS[name].reduced(capacity_factor=64.0)
    model = make_model(cfg, max_dec_seq=S)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)


def test_mla_absorbed_decode_matches_forward():
    cfg = ARCHS["deepseek-v2-236b"].reduced(capacity_factor=64.0)
    model = make_model(cfg, max_dec_seq=S)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("name", ["mamba2-1.3b", "jamba-v0.1-52b"])
def test_ssm_hybrid_decode_matches_forward(name):
    cfg = ARCHS[name].reduced(ssm_chunk=8, capacity_factor=64.0)  # S=24 -> 3 chunks
    model = make_model(cfg, max_dec_seq=S)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=5e-3, atol=5e-3)


def test_encdec_decode_matches_forward():
    cfg = ARCHS["whisper-small"].reduced()
    model = make_model(cfg, max_dec_seq=S)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc_out = encdec_mod.encode(params, cfg, frames)
    fwd_logits = encdec_mod.decoder_forward(params, cfg, tokens, enc_out)
    cache = encdec_mod.init_encdec_cache(params, cfg, frames, S)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer decode == full forward with a sliding-window mask."""
    cfg = ARCHS["tinyllama-1.1b"].reduced(window=8,
                                          attention_variant="sliding")
    model = make_model(cfg, max_dec_seq=S)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S)
    assert cache.layers["kv_0"].k.shape[2] == 8   # ring buffer, not S
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)
