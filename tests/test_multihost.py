"""Multi-controller training + serving, proven by a simulated fleet.

The paper's deployment claim (§4) is that Algorithm 1 distributes as an
AllReduce of O(m) vectors over partitioned data, tolerating worker loss.
These tests reproduce that claim on one machine: N subprocesses, each a
"host" with its own fake local devices, joined by ``jax.distributed``
into one global mesh (tests/multihost/rig.py).

Three properties are load-bearing:

* **Parity** — the fit over 2 and 4 processes matches the single-process
  beta to 1e-4 relative, and 2-process x 2-device equals 4-process x
  1-device *bitwise* (same 4-device global mesh, same reduction order):
  the distribution layer changes where rows live, not the math.
* **O(m) traffic** — the cross-host payload of one training chunk
  evaluation is counted from the traced jaxpr (not claimed): a handful
  of m-vectors, independent of chunk_rows; a served request moves
  O(batch) bytes, independent of m.
* **Fail fast** — SIGKILLing a worker mid-collective surfaces a clean,
  attributable error within the watchdog budget instead of a hang.
"""
import numpy as np
import pytest

from multihost.rig import FleetError, run_fleet

pytestmark = [pytest.mark.slow,
              pytest.mark.requires_devices(4),
              pytest.mark.requires_multiprocess(timeout=1500)]

PLANS = ("stream", "otf_shard")


def _rel_l2(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


@pytest.mark.parametrize("plan", PLANS)
def test_multihost_parity_and_elasticity(plan):
    """2- and 4-process fits match 1-process at 1e-4 rel; 2x2 == 4x1
    bitwise. All three fleets share the 4-device global mesh."""
    ref = run_fleet("fit", 1, 4, extra=[plan]).result
    two = run_fleet("fit", 2, 2, extra=[plan]).result
    four = run_fleet("fit", 4, 1, extra=[plan]).result

    assert ref["n_devices"] == two["n_devices"] == four["n_devices"] == 4
    assert two["num_processes"] == 2 and four["num_processes"] == 4
    rel2 = _rel_l2(two["beta"], ref["beta"])
    rel4 = _rel_l2(four["beta"], ref["beta"])
    assert rel2 < 1e-4, f"2-process beta diverged: rel l2 {rel2:.2e}"
    assert rel4 < 1e-4, f"4-process beta diverged: rel l2 {rel4:.2e}"
    # process count is a deployment knob, not a numerical one: identical
    # global device count -> identical reduction order -> identical bits
    assert two["beta_sha"] == four["beta_sha"], \
        "2proc x 2dev and 4proc x 1dev disagree bitwise on the same mesh"


def test_multihost_collective_payload_is_o_m():
    """Counted from the traced jaxpr on a real 2-process spanning mesh:
    training moves O(m) bytes per chunk evaluation (f/g psums), serving
    moves O(batch) bytes per request — never O(n), never O(chunk_rows)."""
    out = run_fleet("payload", 2, 2).result
    m, itemsize = out["m"], out["itemsize"]
    # f/g: one scalar + one (m,) psum; Hd: one (m,) psum. c=4 leaves room
    # for an implementation to psum one extra m-vector, not a data-sized one.
    assert 0 < out["fg_chunk_bytes"] <= 4 * m * itemsize, out
    assert 0 < out["hd_chunk_bytes"] <= 4 * m * itemsize, out
    assert out["fg_chunk_bytes"] < out["chunk_rows"] * itemsize, \
        "per-chunk traffic scales with the data partition, not with m"
    assert 0 < out["serve_request_bytes"] <= 4 * out["max_batch"] * itemsize, \
        out


def test_multihost_worker_death_fails_fast():
    """SIGKILL one worker mid-lockstep: the fleet must fail attributably
    within the watchdog budget — never hang until the test timeout."""
    with pytest.raises(FleetError) as ei:
        run_fleet("spin", 2, 1, kill=(1, 8.0), timeout=120)
    err = ei.value
    assert err.returncodes[1] == -9, err.returncodes
    assert "process 1" in str(err)
    assert err.elapsed < 90, \
        f"death took {err.elapsed:.1f}s to surface (watchdog asleep?)"
