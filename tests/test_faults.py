"""repro.faults: deterministic fault injection + the recovery it exercises.

Three layers, in order:

1. plan mechanics — counting (times/after/every), seeded probability,
   once-across-processes flag files, JSON/env round-trips, the kill
   action (proven in a sacrificial subprocess);
2. retried chunk reads — a stream fit under transient ``chunk.read``
   faults below the retry cap is *bitwise identical* to the clean fit,
   and a persistent fault still surfaces as an OSError;
3. checkpoint commits — transient faults are absorbed by the async
   writer's retry, torn commits leave garbage that ``load_latest`` skips,
   and secondary I/O failures warn instead of vanishing (satellite 1).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import faults
from repro.api import KernelMachine, MachineConfig, StreamConfig
from repro.checkpoint import (AsyncCheckpointWriter, list_steps, load_latest,
                              prune_steps, save_checkpoint, write_step)
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_classification
from repro.data.chunks import MmapChunkSource, save_chunks
from repro.faults import FAULT_ENV, FaultPlan, FaultRule

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


# ----------------------------------------------------------- plan mechanics
def test_rule_validation():
    with pytest.raises(ValueError, match="action"):
        FaultRule(site="x", action="explode")
    with pytest.raises(ValueError, match="exception"):
        FaultRule(site="x", exc="SystemExit")
    with pytest.raises(ValueError, match="every"):
        FaultRule(site="x", every=0)
    with pytest.raises(ValueError, match="times"):
        FaultRule(site="x", times=0)


def test_counting_gate_after_every_times():
    plan = FaultPlan().inject("s", after=2, every=2, times=2)
    # calls 1,2 clean (after); then every 2nd eligible call: 3,5 fire
    fired = [plan.consult("s") is not None for _ in range(8)]
    assert fired == [False, False, True, False, True, False, False, False]
    assert plan.stats() == {"calls": {"s": 8}, "fired": {"s": 2}}


def test_persistent_rule_fires_forever():
    plan = FaultPlan().inject("s", times=None)
    assert all(plan.consult("s") is not None for _ in range(20))


def test_sites_are_counted_independently():
    plan = FaultPlan().inject("a", times=1)
    assert plan.consult("b") is None          # other site: no fire, no spend
    assert plan.consult("a") is not None
    assert plan.consult("a") is None          # budget of 1 spent


def test_probability_is_seeded_and_reproducible():
    plan1 = FaultPlan(seed=7).inject("s", probability=0.5, times=None)
    pat1 = [plan1.consult("s") is not None for _ in range(40)]
    plan2 = FaultPlan(seed=7).inject("s", probability=0.5, times=None)
    pat2 = [plan2.consult("s") is not None for _ in range(40)]
    assert pat1 == pat2
    assert 0 < sum(pat1) < 40                 # actually a coin, not a constant
    plan3 = FaultPlan(seed=8).inject("s", probability=0.5, times=None)
    pat3 = [plan3.consult("s") is not None for _ in range(40)]
    assert pat1 != pat3


def test_flag_file_means_once_across_plans(tmp_path):
    """The restart scenario: a restarted worker builds a *fresh* plan from
    REPRO_FAULTS but must not re-fire a flag-guarded rule."""
    flag = str(tmp_path / "fired-once")
    assert FaultPlan().inject("s", flag=flag, times=None).consult("s")
    # second process (modeled as a second plan instance), same flag: clean
    plan2 = FaultPlan().inject("s", flag=flag, times=None)
    assert all(plan2.consult("s") is None for _ in range(5))


def test_json_round_trip_and_schedule():
    plan = (FaultPlan(seed=3)
            .inject("chunk.read", times=2, exc="TimeoutError")
            .kill(1, 2.5).stall(0, 1.0, 0.5))
    back = FaultPlan.from_json(plan.to_json())
    assert back.seed == 3
    assert back.rules == plan.rules
    assert back.schedule == [
        {"kind": "kill", "pid": 1, "at": 2.5},
        {"kind": "stall", "pid": 0, "at": 1.0, "duration": 0.5}]


def test_fire_fast_path_and_context_manager():
    faults.uninstall()
    assert faults.fire("anything") is None          # no plan installed
    with FaultPlan().inject("s", exc="TimeoutError", message="boom") as plan:
        assert faults.active() is plan
        with pytest.raises(TimeoutError, match="boom"):
            faults.fire("s")
        assert faults.fire("s") is None             # budget spent
    assert faults.active() is None                  # context exit uninstalls


def test_kill_action_sigkills_process():
    """kill is proven on a sacrificial subprocess; the plan rides in via
    REPRO_FAULTS, which also covers the import-time env activation path."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env[FAULT_ENV] = FaultPlan().inject("x", action="kill").to_json()
    p = subprocess.run(
        [sys.executable, "-c",
         "import repro.faults as f; f.fire('x'); print('survived')"],
        env=env, capture_output=True, text=True, timeout=60)
    assert p.returncode == -9
    assert "survived" not in p.stdout


# ------------------------------------------------- retried stream chunk I/O
N, D, M = 256, 8, 16
STREAM_CFG = MachineConfig(
    kernel=KernelSpec("gaussian", sigma=2.0), lam=0.5, plan="stream",
    tron=TronConfig(max_iter=40),
    stream=StreamConfig(chunk_rows=64))


@pytest.fixture(scope="module")
def stream_setup(tmp_path_factory):
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=2)
    X, y = np.asarray(X), np.asarray(y)
    d = tmp_path_factory.mktemp("fault-shards")
    save_chunks(d, X, y, rows_per_shard=100)
    basis = np.asarray(random_basis(jax.random.PRNGKey(1), jnp.asarray(X), M))
    return d, basis


def test_transient_chunk_faults_change_no_result_bit(stream_setup):
    """Acceptance: transient chunk-read faults below the retry cap are
    invisible in the result — the faulted fit's beta is bitwise identical.
    times=2 is the max a single read survives under max_attempts=3."""
    shard_dir, basis = stream_setup
    clean = KernelMachine(STREAM_CFG).fit(
        MmapChunkSource(shard_dir, chunk_rows=64), None, basis)
    plan = FaultPlan().inject("chunk.read", times=2)
    with plan:
        faulted = KernelMachine(STREAM_CFG).fit(
            MmapChunkSource(shard_dir, chunk_rows=64), None, basis)
    assert plan.stats()["fired"].get("chunk.read", 0) >= 1
    np.testing.assert_array_equal(np.asarray(clean.state_["beta"]),
                                  np.asarray(faulted.state_["beta"]))


def test_persistent_chunk_fault_exhausts_retries(stream_setup):
    shard_dir, basis = stream_setup
    with FaultPlan().inject("chunk.read", times=None,
                            message="disk gone"):
        with pytest.raises(OSError, match="disk gone"):
            KernelMachine(STREAM_CFG).fit(
                MmapChunkSource(shard_dir, chunk_rows=64), None, basis)


# ------------------------------------------------------- checkpoint commits
def _tree(step):
    return {"beta": np.full(4, float(step)), "it": np.asarray(step)}


def test_async_writer_absorbs_transient_commit_fault(tmp_path):
    d = str(tmp_path / "steps")
    with FaultPlan().inject("ckpt.commit", times=1):
        w = AsyncCheckpointWriter(
            lambda s, t, m: write_step(d, s, t, m, fsync=False))
        w.submit(1, _tree(1), {})
        assert w.flush(timeout=30.0)
        w.close()
    st = w.stats()
    assert st["errors"] == 0
    assert st["write_retries"] >= 1
    assert st["snapshots_written"] == 1
    assert [s for s, _ in list_steps(d)] == [1]


def test_torn_commit_leaves_garbage_load_latest_skips(tmp_path):
    """torn models a non-atomic writer dying mid-commit: garbage lands at
    the destination and resume must fall back to the older clean step."""
    d = str(tmp_path / "steps")
    snap_tree = {"beta": np.ones(3), "delta": np.asarray(1.0),
                 "gnorm0": np.asarray(1.0), "active": np.ones(3, bool),
                 "it": np.asarray(1), "n_fg": np.asarray(1),
                 "n_hd": np.asarray(1)}
    write_step(d, 1, snap_tree, {}, fsync=False)
    with FaultPlan().inject("ckpt.commit", action="torn", times=None):
        with pytest.raises(OSError, match="torn"):
            write_step(d, 2, snap_tree, {}, fsync=False)
    # the torn file exists (it is garbage), but resume skips over it
    assert [s for s, _ in list_steps(d)] == [1, 2]
    assert load_latest(d).step == 1


def test_cleanup_failure_warns_instead_of_vanishing(tmp_path, monkeypatch):
    """Satellite 1: the commit failure propagates, and the *secondary*
    failure (tmp file that couldn't be removed) is warned + sunk, not
    silently swallowed."""
    sink = []

    def bad(*a, **k):
        raise OSError("disk detached")

    monkeypatch.setattr(os, "replace", bad)
    monkeypatch.setattr(os, "unlink", bad)
    with pytest.raises(OSError, match="disk detached"):
        with pytest.warns(RuntimeWarning, match="tmp-cleanup"):
            save_checkpoint(str(tmp_path / "c.npz"), _tree(1), fsync=False,
                            on_io_warning=lambda *a: sink.append(a))
    assert len(sink) == 1 and sink[0][0] == "tmp-cleanup"


def test_prune_failure_warns_and_keeps_going(tmp_path, monkeypatch):
    d = str(tmp_path / "steps")
    for s in (1, 2, 3):
        write_step(d, s, _tree(s), {}, fsync=False)
    monkeypatch.setattr(
        os, "unlink", lambda p: (_ for _ in ()).throw(OSError("ro fs")))
    sink = []
    with pytest.warns(RuntimeWarning, match="prune-unlink"):
        removed = prune_steps(d, keep=1, on_io_warning=lambda *a:
                              sink.append(a))
    assert removed == 0
    assert len(sink) == 2                       # steps 1 and 2 both reported
    assert [s for s, _ in list_steps(d)] == [1, 2, 3]
